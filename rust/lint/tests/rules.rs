//! End-to-end tests of the compiled `dreamshard-lint` binary: every rule
//! has a known-bad fixture asserted down to the exact `(file, line,
//! rule)` triples it must report, a known-good fixture that must stay
//! silent (string/comment traps, path exemptions, pragma escapes), the
//! interprocedural rules have a cross-file pair that only fails when
//! linted together, the `--json` document round-trips through a real
//! parser, and the real tree must lint clean under the full default walk
//! — the same contract CI gates with `cargo run -p dreamshard-lint`.

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::process::Command;

fn fixture(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(rel)
}

/// Run the binary with `flags` + `paths`, returning the exit code and
/// raw stdout.
fn run_lint(flags: &[&str], paths: &[PathBuf]) -> (Option<i32>, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_dreamshard-lint"))
        .args(flags)
        .args(paths)
        .output()
        .expect("spawn dreamshard-lint");
    (out.status.code(), String::from_utf8_lossy(&out.stdout).into_owned())
}

fn rel_fixture(file: &str) -> String {
    let file = file.replace('\\', "/");
    file.rsplit_once("tests/fixtures/").map(|(_, r)| r.to_string()).unwrap_or(file)
}

/// Text-mode run, parsed into fixture-relative `(file, line, rule)`.
fn lint(paths: &[PathBuf]) -> (Option<i32>, BTreeSet<(String, u32, String)>) {
    let (code, stdout) = run_lint(&[], paths);
    let mut hits = BTreeSet::new();
    for l in stdout.lines() {
        // `<path>:<line>: <rule>: <message>`
        let mut parts = l.splitn(3, ": ");
        let file_line = parts.next().expect("file:line field");
        let rule = parts.next().expect("rule field").to_string();
        assert!(parts.next().is_some(), "missing message in `{l}`");
        let (file, line) = file_line.rsplit_once(':').expect("line suffix");
        hits.insert((rel_fixture(file), line.parse().expect("numeric line"), rule));
    }
    (code, hits)
}

fn expected(entries: &[(&str, u32, &str)]) -> BTreeSet<(String, u32, String)> {
    entries.iter().map(|&(f, l, r)| (f.to_string(), l, r.to_string())).collect()
}

#[test]
fn bad_fixtures_flag_exact_lines() {
    let (code, hits) = lint(&[fixture("bad")]);
    assert_eq!(code, Some(1), "bad fixtures must fail the gate");
    assert_eq!(
        hits,
        expected(&[
            ("bad/envy.rs", 4, "env-discipline"),
            ("bad/envy.rs", 8, "env-discipline"),
            ("bad/lock.rs", 5, "lock-across-wait"),
            ("bad/lock.rs", 11, "lock-across-wait"),
            ("bad/lock_order.rs", 6, "lock-order"),
            ("bad/lock_order.rs", 11, "lock-order"),
            ("bad/nan.rs", 4, "nan-ordering"),
            ("bad/nan.rs", 9, "nan-ordering"),
            ("bad/nan.rs", 14, "nan-ordering"),
            ("bad/nan.rs", 18, "nan-ordering"),
            ("bad/nan.rs", 22, "nan-ordering"),
            ("bad/placer/map_iter.rs", 10, "map-iter-determinism"),
            ("bad/pragmas.rs", 4, "pragma"),
            ("bad/pragmas.rs", 5, "nan-ordering"),
            ("bad/pragmas.rs", 9, "pragma"),
            ("bad/pragmas.rs", 10, "nan-ordering"),
            ("bad/serve/clocky.rs", 4, "clock-transitive"),
            ("bad/serve/clocky.rs", 8, "clock-transitive"),
            ("bad/serve/leak.rs", 5, "clock-transitive"),
            ("bad/serve/panics.rs", 4, "panic-policy"),
            ("bad/serve/panics.rs", 8, "panic-policy"),
            ("bad/serve/panics.rs", 12, "panic-policy"),
            ("bad/serve/swallow.rs", 8, "swallowed-result"),
        ]),
    );
}

#[test]
fn good_fixtures_are_clean() {
    let (code, hits) = lint(&[fixture("good")]);
    assert_eq!(hits, BTreeSet::new(), "good fixtures must produce no violations");
    assert_eq!(code, Some(0));
}

#[test]
fn each_bad_fixture_fails_alone() {
    // the cross-file pair (serve/leak.rs + timeutil.rs) is deliberately
    // absent: each half is clean alone (see the pair test below)
    let files = [
        "nan.rs",
        "serve/clocky.rs",
        "envy.rs",
        "serve/panics.rs",
        "serve/swallow.rs",
        "lock.rs",
        "lock_order.rs",
        "placer/map_iter.rs",
        "pragmas.rs",
    ];
    for f in files {
        let (code, hits) = lint(&[fixture("bad").join(f)]);
        assert_eq!(code, Some(1), "{f} must fail on its own");
        assert!(!hits.is_empty(), "{f} must report at least one violation");
    }
}

/// The interprocedural contract in one test: a serve/ caller and the
/// raw-clock helper it reaches are each clean in isolation, and the
/// violation appears — at the call site — only when the analyzer sees
/// both files as one program.
#[test]
fn cross_file_leak_needs_both_halves() {
    let leak = fixture("bad/serve/leak.rs");
    let util = fixture("bad/timeutil.rs");
    let (code, hits) = lint(&[leak.clone()]);
    assert_eq!((code, hits.len()), (Some(0), 0), "caller half must be clean alone");
    let (code, hits) = lint(&[util.clone()]);
    assert_eq!((code, hits.len()), (Some(0), 0), "helper half must be clean alone");
    let (code, hits) = lint(&[leak, util]);
    assert_eq!(code, Some(1));
    assert_eq!(hits, expected(&[("bad/serve/leak.rs", 5, "clock-transitive")]));
}

#[test]
fn missing_path_is_a_usage_error() {
    let (code, hits) = lint(&[fixture("no/such/path")]);
    assert_eq!(code, Some(2), "unknown roots are an IO error, not a lint pass");
    assert!(hits.is_empty());
}

#[test]
fn unknown_flag_is_a_usage_error() {
    let (code, stdout) = run_lint(&["--no-such-flag"], &[]);
    assert_eq!(code, Some(2));
    assert!(stdout.is_empty());
}

#[test]
fn quiet_suppresses_findings_but_not_the_exit_code() {
    let (code, stdout) = run_lint(&["--quiet"], &[fixture("bad")]);
    assert_eq!(code, Some(1), "--quiet must not change the verdict");
    assert!(stdout.is_empty(), "--quiet must print no per-violation lines");
}

// ---------------------------------------------------------------------
// --json round trip
// ---------------------------------------------------------------------

/// Just enough JSON to parse the documented schema (objects, arrays,
/// escaped strings, non-negative integers) — so the round trip proves
/// the emitter produces real JSON, not something JSON-shaped.
#[derive(Debug, PartialEq)]
enum Json {
    Num(u64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }
    fn eat(&mut self, c: u8) {
        self.ws();
        assert_eq!(self.s.get(self.i), Some(&c), "expected `{}` at byte {}", c as char, self.i);
        self.i += 1;
    }
    fn peek(&mut self) -> u8 {
        self.ws();
        *self.s.get(self.i).expect("unexpected end of JSON")
    }
    fn value(&mut self) -> Json {
        match self.peek() {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Json::Str(self.string()),
            _ => self.number(),
        }
    }
    fn object(&mut self) -> Json {
        self.eat(b'{');
        let mut kv = Vec::new();
        if self.peek() != b'}' {
            loop {
                let k = self.string();
                self.eat(b':');
                kv.push((k, self.value()));
                if self.peek() != b',' {
                    break;
                }
                self.eat(b',');
            }
        }
        self.eat(b'}');
        Json::Obj(kv)
    }
    fn array(&mut self) -> Json {
        self.eat(b'[');
        let mut items = Vec::new();
        if self.peek() != b']' {
            loop {
                items.push(self.value());
                if self.peek() != b',' {
                    break;
                }
                self.eat(b',');
            }
        }
        self.eat(b']');
        Json::Arr(items)
    }
    fn string(&mut self) -> String {
        self.eat(b'"');
        let mut out = String::new();
        loop {
            let c = self.s[self.i];
            self.i += 1;
            match c {
                b'"' => break,
                b'\\' => {
                    let e = self.s[self.i];
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.s[self.i..self.i + 4]).unwrap();
                            self.i += 4;
                            let cp = u32::from_str_radix(hex, 16).expect("hex escape");
                            out.push(char::from_u32(cp).expect("scalar escape"));
                        }
                        other => panic!("unsupported escape `\\{}`", other as char),
                    }
                }
                c => {
                    // re-assemble multi-byte UTF-8 sequences
                    let len = match c {
                        0x00..=0x7f => 0,
                        0xc0..=0xdf => 1,
                        0xe0..=0xef => 2,
                        _ => 3,
                    };
                    let bytes = &self.s[self.i - 1..self.i + len];
                    self.i += len;
                    out.push_str(std::str::from_utf8(bytes).expect("utf8 string"));
                }
            }
        }
        out
    }
    fn number(&mut self) -> Json {
        self.ws();
        let start = self.i;
        while self.i < self.s.len() && self.s[self.i].is_ascii_digit() {
            self.i += 1;
        }
        assert!(self.i > start, "expected a number at byte {start}");
        Json::Num(std::str::from_utf8(&self.s[start..self.i]).unwrap().parse().unwrap())
    }
}

fn parse_json(s: &str) -> Json {
    let mut p = Parser { s: s.as_bytes(), i: 0 };
    let v = p.value();
    p.ws();
    assert_eq!(p.i, s.len(), "trailing bytes after JSON document");
    v
}

fn field<'j>(obj: &'j Json, key: &str) -> &'j Json {
    match obj {
        Json::Obj(kv) => {
            &kv.iter().find(|(k, _)| k == key).unwrap_or_else(|| panic!("missing key `{key}`")).1
        }
        other => panic!("expected object for `{key}`, got {other:?}"),
    }
}

fn count_rs(dir: &PathBuf) -> usize {
    let mut n = 0;
    for e in std::fs::read_dir(dir).expect("read fixture dir") {
        let p = e.expect("dir entry").path();
        if p.is_dir() {
            n += count_rs(&p);
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            n += 1;
        }
    }
    n
}

/// `--json` must agree with text mode finding-for-finding, carry the
/// documented `version`/`files_checked` fields, and parse as real JSON.
#[test]
fn json_output_round_trips() {
    let (text_code, text_hits) = lint(&[fixture("bad")]);
    let (json_code, stdout) = run_lint(&["--json"], &[fixture("bad")]);
    assert_eq!(json_code, text_code);

    let doc = parse_json(&stdout);
    assert_eq!(field(&doc, "version"), &Json::Num(1));
    assert_eq!(field(&doc, "files_checked"), &Json::Num(count_rs(&fixture("bad")) as u64));

    let Json::Arr(viols) = field(&doc, "violations") else { panic!("violations not an array") };
    let mut json_hits = BTreeSet::new();
    for v in viols {
        let Json::Str(file) = field(v, "file") else { panic!("file not a string") };
        let Json::Num(line) = field(v, "line") else { panic!("line not a number") };
        let Json::Str(rule) = field(v, "rule") else { panic!("rule not a string") };
        let Json::Str(msg) = field(v, "message") else { panic!("message not a string") };
        assert!(!msg.is_empty(), "every violation carries a message");
        json_hits.insert((rel_fixture(file), *line as u32, rule.clone()));
    }
    assert_eq!(json_hits, text_hits, "--json and text mode must agree");
}

/// `--github` renders one workflow command per finding, in the
/// `::error file=..,line=..,title=..::message` shape CI annotates with.
#[test]
fn github_annotations_format() {
    let (code, stdout) = run_lint(&["--github"], &[fixture("bad/serve/clocky.rs")]);
    assert_eq!(code, Some(1));
    let lines: Vec<&str> = stdout.lines().collect();
    assert_eq!(lines.len(), 2, "one annotation per finding: {stdout}");
    for (l, want_line) in lines.iter().zip([4, 8]) {
        assert!(l.starts_with("::error file="), "workflow command prefix: {l}");
        assert!(l.contains(&format!(",line={want_line},")), "line property: {l}");
        assert!(l.contains("title=dreamshard-lint clock-transitive::"), "title + separator: {l}");
        let msg = l.split_once("::").and_then(|(_, r)| r.split_once("::")).map(|(_, m)| m);
        assert!(!msg.unwrap_or("").is_empty(), "annotation message survives escaping: {l}");
    }
}

// ---------------------------------------------------------------------
// The real tree
// ---------------------------------------------------------------------

/// The gate CI enforces, from inside the test suite: the real sources
/// (including this crate's own) carry zero violations.
#[test]
fn real_tree_is_clean() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let (code, hits) = lint(&[root.join("../src"), root.join("src")]);
    assert_eq!(hits, BTreeSet::new(), "rust/src and rust/lint/src must lint clean");
    assert_eq!(code, Some(0));
}

/// Regression pin for the v2 widening: the full default walk —
/// `rust/src`, `rust/lint/src`, `benches/`, `examples/`, `rust/tests/`
/// — lints clean from the repo root, interprocedural rules included.
#[test]
fn full_default_walk_is_clean() {
    let repo_root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..");
    let out = Command::new(env!("CARGO_BIN_EXE_dreamshard-lint"))
        .current_dir(&repo_root)
        .output()
        .expect("spawn dreamshard-lint");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(stdout.as_ref(), "", "default walk must report nothing");
    assert_eq!(out.status.code(), Some(0));
}
