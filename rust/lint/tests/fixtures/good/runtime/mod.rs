// Good: runtime/mod.rs is a sanctioned env read site.

pub fn workers() -> Option<String> {
    std::env::var("DREAMSHARD_WORKERS").ok()
}
