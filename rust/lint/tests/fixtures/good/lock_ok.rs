// Good: guards are dropped or scope-closed before pool dispatch.

pub fn drop_then_wait(m: &std::sync::Mutex<u32>, t: &Ticket) -> u32 {
    let g = m.lock().unwrap_or_else(|e| e.into_inner());
    let held = *g;
    drop(g);
    t.wait() + held
}

pub fn scope_then_submit(m: &std::sync::Mutex<u32>, rt: &Runtime) {
    {
        let mut g = m.lock().unwrap_or_else(|e| e.into_inner());
        *g += 1;
    }
    rt.submit("step", vec![]);
}
