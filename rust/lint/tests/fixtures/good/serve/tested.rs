// Good: test code inside a serve/ path is exempt from panic-policy.

pub fn lib_path(v: Option<u32>) -> Option<u32> {
    v
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwraps_freely() {
        Some(1u32).unwrap();
        assert!(std::panic::catch_unwind(|| panic!("in a test")).is_err());
    }
}
