// Good: serve code reads time through the Clock seam.

pub fn stamp(clock: &dyn Clock) -> Instant {
    clock.now()
}

pub fn justified() -> std::time::Instant {
    // lint: allow(clock-transitive) — diagnostics only, never replayed
    std::time::Instant::now()
}
