// Good: serve/clock.rs is the sanctioned home of raw clock reads.

pub fn now() -> std::time::Instant {
    std::time::Instant::now()
}
