// Good: serve/ routes in-crate Results through `?` (or justifies the
// drop); discarding a unit-returning call is not a violation.

impl Dispatcher {
    fn requeue_all(&mut self) -> Result<usize> {
        Ok(0)
    }
    fn log_tick(&mut self) {
    }
    fn on_tick(&mut self) -> Result<usize> {
        self.log_tick();
        let n = self.requeue_all()?;
        // lint: allow(swallowed-result) — best-effort refresh, retried next tick
        let _ = self.requeue_all();
        Ok(n)
    }
}
