// Good: deterministic containers iterate freely; a HashSet fold is
// pragma-justified as order-insensitive.

pub struct Loads {
    by_dev: BTreeMap<usize, f32>,
    seen: HashSet<usize>,
}

pub fn spread(l: &Loads) -> f32 {
    let mut acc = 0.0;
    for (_, v) in l.by_dev.iter() {
        acc += v;
    }
    // lint: allow(map-iter-determinism) — order-insensitive sum
    for d in l.seen.iter() {
        acc += *d as f32;
    }
    acc
}
