// Good: NaN-safe orderings, plus traps that must not match.

pub fn sort_floats(v: &mut [f64]) {
    v.sort_by(|a, b| a.total_cmp(b));
}

pub fn in_prose() -> &'static str {
    // a.partial_cmp(&b).unwrap() in a comment is fine
    "a.partial_cmp(&b).unwrap() in a string is fine"
}

pub fn raw_trap() -> &'static str {
    r#"v.sort_by(|a, b| a.partial_cmp(b).unwrap())"#
}

pub fn justified(a: f64, b: f64) -> std::cmp::Ordering {
    // lint: allow(nan-ordering) — inputs are clamped upstream, NaN impossible
    a.partial_cmp(&b).unwrap()
}

pub fn partial_no_unwrap(a: f64, b: f64) -> Option<std::cmp::Ordering> {
    a.partial_cmp(&b)
}
