// Good: the bench/ harness is a sanctioned env read site.

pub fn samples() -> Option<String> {
    std::env::var("BENCH_SAMPLES").ok()
}
