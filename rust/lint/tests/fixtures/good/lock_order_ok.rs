// Good: every path takes alpha before beta — one global acquisition
// order, directly or through a helper, so the lock graph is acyclic.

pub fn forward(s: &S) {
    let ga = s.alpha.lock().unwrap_or_else(|e| e.into_inner());
    let gb = s.beta.lock().unwrap_or_else(|e| e.into_inner());
}

pub fn also_forward(s: &S) {
    let ga = s.alpha.lock().unwrap_or_else(|e| e.into_inner());
    grab_beta(s);
}

fn grab_beta(s: &S) {
    let gb = s.beta.lock().unwrap_or_else(|e| e.into_inner());
}
