// Bad: every nan-ordering shape the rule must catch.

pub fn cmp_split(a: f64, b: f64) -> std::cmp::Ordering {
    a.partial_cmp(&b).unwrap()
}

pub fn cmp_multiline(a: f64, b: f64) -> std::cmp::Ordering {
    a
        .partial_cmp(&b)
        .unwrap()
}

pub fn cmp_expect(a: f64, b: f64) -> std::cmp::Ordering {
    a.partial_cmp(&b).expect("comparable")
}

pub fn sort_floats(v: &mut [f64]) {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

pub fn max_float(v: &[f64]) -> Option<&f64> {
    v.iter().max_by(|a, b| a.partial_cmp(b).unwrap())
}
