// Bad: env reads outside runtime/mod.rs and bench/.

pub fn workers() -> Option<String> {
    std::env::var("DREAMSHARD_WORKERS").ok()
}

pub fn artifacts() -> Option<std::ffi::OsString> {
    std::env::var_os("DREAMSHARD_ARTIFACTS")
}
