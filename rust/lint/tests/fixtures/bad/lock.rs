// Bad: lock guards held across pool dispatch.

pub fn wait_with_guard(m: &std::sync::Mutex<u32>, t: &Ticket) -> u32 {
    let g = m.lock().unwrap_or_else(|e| e.into_inner());
    let r = t.wait();
    *g + r
}

pub fn submit_with_guard(m: &std::sync::Mutex<u32>, rt: &Runtime) {
    let mut g = m.lock().unwrap_or_else(|e| e.into_inner());
    let t = rt.submit("step", vec![]);
    *g += t.id();
}
