// Bad half of a cross-file pair: a raw clock behind a helper. Not in
// serve/, so never flagged directly — the violation appears at the
// serve/leak.rs call site that reaches it.

pub fn monotonic_ms() -> u64 {
    let t = std::time::Instant::now();
    t.elapsed().as_millis() as u64
}
