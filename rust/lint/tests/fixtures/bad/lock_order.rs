// Bad: the global lock graph has a cycle — `forward` takes alpha then
// beta directly; `backward` takes beta then alpha through a helper.

pub fn forward(s: &S) {
    let ga = s.alpha.lock().unwrap_or_else(|e| e.into_inner());
    let gb = s.beta.lock().unwrap_or_else(|e| e.into_inner());
}

pub fn backward(s: &S) {
    let gb = s.beta.lock().unwrap_or_else(|e| e.into_inner());
    grab_alpha(s);
}

fn grab_alpha(s: &S) {
    let ga = s.alpha.lock().unwrap_or_else(|e| e.into_inner());
}
