// Bad: iterating a HashMap in plan-producing placer/ code — the
// randomized order can leak into device assignments.

pub struct Loads {
    by_dev: HashMap<usize, f32>,
}

pub fn spread(l: &Loads) -> f32 {
    let mut acc = 0.0;
    for (_, v) in l.by_dev.iter() {
        acc += v;
    }
    acc
}
