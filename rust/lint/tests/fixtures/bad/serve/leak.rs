// Bad: this serve/ call site reaches a raw clock through the helper in
// ../timeutil.rs — clean alone, flagged when linted with its pair.

pub fn drain_tick() -> u64 {
    monotonic_ms()
}
