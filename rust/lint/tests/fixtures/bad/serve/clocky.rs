// Bad: raw clock reads inside serve/ outside the Clock seam.

pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}

pub fn wall() -> std::time::SystemTime {
    std::time::SystemTime::now()
}
