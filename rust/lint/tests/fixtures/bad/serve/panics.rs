// Bad: panic-policy violations in a serve/ library path.

pub fn take(v: Option<u32>) -> u32 {
    v.unwrap()
}

pub fn must(v: Option<u32>) -> u32 {
    v.expect("present")
}

pub fn boom() {
    panic!("library hot path");
}
