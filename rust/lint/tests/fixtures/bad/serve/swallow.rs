// Bad: serve/ drops the Result of an in-crate call on the floor.

impl Dispatcher {
    fn requeue_all(&mut self) -> Result<usize> {
        Ok(0)
    }
    fn on_tick(&mut self) {
        let _ = self.requeue_all();
    }
}
