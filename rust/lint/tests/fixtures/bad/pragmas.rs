// Bad: malformed pragma escapes are themselves violations.

pub fn no_reason(a: f64, b: f64) -> std::cmp::Ordering {
    // lint: allow(nan-ordering)
    a.partial_cmp(&b).unwrap()
}

pub fn unknown_rule(a: f64, b: f64) -> std::cmp::Ordering {
    // lint: allow(no-such-rule) — not a rule
    a.partial_cmp(&b).unwrap()
}
