//! Incremental re-placement benchmark: [`Placer::replace_many`] vs
//! re-planning from scratch after a fleet change, on a mixed
//! 2/4/8-device workload where every task with spare devices loses its
//! highest-indexed one. Scratch plans look cheap until the fleet has to
//! *adopt* them — every moved table pays its weights (and optimizer
//! state) over the migration bandwidth — so the comparison tracks both
//! plans/sec and the migration bill. DreamShard's warm-started replace
//! also wins on backend calls: a rebalance chunk rolls only the moved
//! tables through the fused `mdp_step`, so a move budget of K costs
//! `1 + K` calls where a scratch chunk pays `1 + n_tables`.

use dreamshard::bench::common::emit_json;
use dreamshard::coordinator::{DreamShard, TrainCfg};
use dreamshard::placer::{
    self, DreamShardPlacer, MigrationBudget, Placer, PlacementPlan, PlacementRequest,
};
use dreamshard::runtime::Runtime;
use dreamshard::serve::{synthetic_arrivals, WorkloadCfg};
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::tables::{gen_dlrm, split_pools, Dataset, Task};
use dreamshard::util::Rng;
use std::sync::Arc;
use std::time::Instant;

/// (mean latency ms, total migration ms, total moved tables) of adopting
/// `plans` when the fleet currently runs `prevs`.
fn adoption_bill(
    sim: &Simulator,
    ds: &Dataset,
    tasks: &[Task],
    prevs: &[PlacementPlan],
    plans: &[PlacementPlan],
) -> (f64, f64, usize) {
    let mut lat = 0.0;
    let mut mig = 0.0;
    let mut moved = 0usize;
    for ((t, prev), plan) in tasks.iter().zip(prevs).zip(plans) {
        let e = sim.evaluate_migration(ds, t, &prev.placement, &plan.placement);
        lat += e.latency;
        mig += e.migration_ms;
        moved += e.moved_tables;
    }
    (lat / tasks.len().max(1) as f64, mig, moved)
}

fn main() {
    let rt = Arc::new(Runtime::open_default().expect("runtime"));
    let ds = gen_dlrm(400, 0);
    let (pool, _) = split_pools(&ds, 1);
    let sim = Simulator::new(SimConfig::default());
    let arrivals = synthetic_arrivals(&pool, &WorkloadCfg {
        n_requests: 48,
        device_mix: vec![2, 4, 8],
        min_tables: 20,
        max_tables: 40,
        mean_gap_ms: 1.0,
        seed: 3,
        ..WorkloadCfg::default()
    });
    let tasks: Vec<Task> = arrivals.iter().map(|a| a.task.clone()).collect();
    let reqs: Vec<PlacementRequest> = tasks
        .iter()
        .map(|t| PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap())
        .collect();

    // one failed device per task (2-device tasks keep both: their
    // rebalance is purely budget-limited discretionary moves)
    let perturbed: Vec<Task> = tasks
        .iter()
        .map(|t| Task {
            table_ids: t.table_ids.clone(),
            n_devices: if t.n_devices > 2 { t.n_devices - 1 } else { t.n_devices },
        })
        .collect();

    // --- DreamShard: warm-started replace vs scratch re-rollout -------
    let mut rng = Rng::new(0);
    let agent = DreamShard::new(&rt, 8, TrainCfg::default(), &mut rng).unwrap();
    let mut live = DreamShardPlacer::from_agent(&rt, &agent);
    let prevs = live.place_many(&reqs).unwrap();

    for moves in [2usize, 4, 8] {
        let new_reqs: Vec<PlacementRequest> = perturbed
            .iter()
            .map(|t| {
                PlacementRequest::for_runtime(&rt, &ds, t, &sim)
                    .unwrap()
                    .with_migration(MigrationBudget::moves(moves))
            })
            .collect();

        let mut rep = DreamShardPlacer::from_agent(&rt, &agent);
        let calls0 = rt.run_count();
        let t0 = Instant::now(); // lint: allow(clock-transitive) — wall-clock timing section is what this bench measures
        let replaced = rep.replace_many(&prevs, &new_reqs).unwrap();
        let rep_s = t0.elapsed().as_secs_f64();
        let rep_calls = rt.run_count() - calls0;
        let rep_lat: f64 =
            replaced.iter().map(|p| p.eval.latency).sum::<f64>() / replaced.len() as f64;
        let rep_mig: f64 = replaced.iter().map(|p| p.eval.migration_ms).sum();
        let rep_moved: usize = replaced.iter().map(|p| p.eval.moved_tables).sum();

        let mut scr = DreamShardPlacer::from_agent(&rt, &agent);
        let calls0 = rt.run_count();
        let t0 = Instant::now(); // lint: allow(clock-transitive) — wall-clock timing section is what this bench measures
        let scratch = scr.place_many(&new_reqs).unwrap();
        let scr_s = t0.elapsed().as_secs_f64();
        let scr_calls = rt.run_count() - calls0;
        let (scr_lat, scr_mig, scr_moved) =
            adoption_bill(&sim, &ds, &perturbed, &prevs, &scratch);

        println!(
            "dreamshard, {} plans, budget {moves}: replace {:.1} ms ({:.1} plans/s, {} calls, \
             {rep_moved} moved, {rep_mig:.0} ms migration, {rep_lat:.2} ms latency) vs \
             scratch {:.1} ms ({:.1} plans/s, {} calls, {scr_moved} moved, {scr_mig:.0} ms \
             migration, {scr_lat:.2} ms latency)",
            replaced.len(),
            rep_s * 1e3,
            replaced.len() as f64 / rep_s,
            rep_calls,
            scr_s * 1e3,
            scratch.len() as f64 / scr_s,
            scr_calls,
        );
        emit_json(
            &format!("rebalance_replace_budget{moves}"),
            replaced.len() as f64 / rep_s,
            rep_calls,
        );
        emit_json(
            &format!("rebalance_scratch_budget{moves}"),
            scratch.len() as f64 / scr_s,
            scr_calls,
        );
        assert!(
            rep_mig < scr_mig,
            "budgeted replace must migrate less than adopting scratch plans"
        );
    }

    // --- greedy family: migration-aware local search vs re-pack -------
    for name in ["greedy:size", "greedy:size-lookup"] {
        let mut live = placer::by_name(&rt, name).unwrap();
        let prevs = live.place_many(&reqs).unwrap();
        let new_reqs: Vec<PlacementRequest> = perturbed
            .iter()
            .map(|t| {
                PlacementRequest::for_runtime(&rt, &ds, t, &sim)
                    .unwrap()
                    .with_migration(MigrationBudget::moves(4))
            })
            .collect();

        let t0 = Instant::now(); // lint: allow(clock-transitive) — wall-clock timing section is what this bench measures
        let replaced = live.replace_many(&prevs, &new_reqs).unwrap();
        let rep_s = t0.elapsed().as_secs_f64();
        let rep_mig: f64 = replaced.iter().map(|p| p.eval.migration_ms).sum();
        let rep_moved: usize = replaced.iter().map(|p| p.eval.moved_tables).sum();

        let mut scr = placer::by_name(&rt, name).unwrap();
        let t0 = Instant::now(); // lint: allow(clock-transitive) — wall-clock timing section is what this bench measures
        let scratch = scr.place_many(&new_reqs).unwrap();
        let scr_s = t0.elapsed().as_secs_f64();
        let (_, scr_mig, scr_moved) = adoption_bill(&sim, &ds, &perturbed, &prevs, &scratch);

        println!(
            "{name}, {} plans, budget 4: replace {:.1} ms ({rep_moved} moved, {rep_mig:.0} ms \
             migration) vs scratch {:.1} ms ({scr_moved} moved, {scr_mig:.0} ms migration)",
            replaced.len(),
            rep_s * 1e3,
            scr_s * 1e3,
        );
        assert!(rep_mig < scr_mig, "{name}: replace must migrate less than re-packing");
    }
}
