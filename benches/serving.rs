//! Serving-throughput benchmark: plans/sec of the [`PlanService`]
//! lane-batched drain vs sequential per-request planning on the same
//! 64-request mixed-device open-loop workload, plus the pipelined drain
//! vs the blocking drain at 1, 2, and 4 runtime workers (see the
//! ROADMAP's async/pipelined planning item), plus the sharded front end
//! vs a single shared FIFO on a mixed 2/4/8/128-device workload. The
//! batched drain shares one fused `mdp_step` call per MDP step across a
//! chunk's lanes and orders every task in a chunk with one concatenated
//! `table_cost` pass; the pipelined drain additionally fills chunk k+1's
//! feature tensors while chunk k's fused call executes on the worker
//! pool; the sharded drain additionally serves each variant's queue on
//! its own thread so a 128-device chunk never stalls 8-device traffic
//! at the head of one FIFO.

use dreamshard::bench::common::emit_json;
use dreamshard::coordinator::{DreamShard, TrainCfg};
use dreamshard::placer::{DreamShardPlacer, Placer, PlacementRequest};
use dreamshard::runtime::Runtime;
use dreamshard::serve::{
    synthetic_arrivals, Clock, ControlConfig, Controller, PlanService, ServeConfig, ShardConfig,
    ShardedFrontEnd, TestClock, WorkloadCfg,
};
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::tables::{gen_dlrm, split_pools};
use dreamshard::util::Rng;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let rt = Arc::new(Runtime::open_default().expect("runtime"));
    let ds = gen_dlrm(400, 0);
    let (pool, _) = split_pools(&ds, 1);
    let sim = Simulator::new(SimConfig::default());
    let arrivals = synthetic_arrivals(&pool, &WorkloadCfg {
        n_requests: 64,
        device_mix: vec![2, 4, 8],
        min_tables: 20,
        max_tables: 40,
        mean_gap_ms: 1.0,
        seed: 3,
        ..WorkloadCfg::default()
    });
    let mut rng = Rng::new(0);
    let agent = DreamShard::new(&rt, 8, TrainCfg::default(), &mut rng).unwrap();
    let reqs: Vec<PlacementRequest> = arrivals
        .iter()
        .map(|a| PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim).unwrap())
        .collect();

    // sequential baseline: one full episode per request
    let mut seq = DreamShardPlacer::from_agent(&rt, &agent);
    for r in reqs.iter().take(4) {
        seq.place(r).unwrap(); // warm
    }
    let calls_before = rt.run_count();
    let t0 = Instant::now(); // lint: allow(clock-transitive) — wall-clock timing section is what this bench measures
    for r in &reqs {
        seq.place(r).unwrap();
    }
    let seq_s = t0.elapsed().as_secs_f64();
    let seq_calls = rt.run_count() - calls_before;

    // service: variant-grouped lane-chunks through place_many
    let run = |chunk: usize| {
        let mut svc = PlanService::new(
            &rt,
            Box::new(DreamShardPlacer::from_agent(&rt, &agent)),
            ServeConfig { capacity: reqs.len(), chunk, ..ServeConfig::default() },
        );
        for r in &reqs {
            svc.submit(*r).unwrap();
        }
        let calls_before = rt.run_count();
        let t0 = Instant::now(); // lint: allow(clock-transitive) — wall-clock timing section is what this bench measures
        let done = svc.drain_blocking().unwrap();
        assert_eq!(done.len(), reqs.len());
        (t0.elapsed().as_secs_f64(), rt.run_count() - calls_before)
    };
    run(16); // warm
    emit_json("serve_sequential", reqs.len() as f64 / seq_s, seq_calls);
    for chunk in [4usize, 16] {
        let (bat_s, bat_calls) = run(chunk);
        emit_json(&format!("serve_batched_chunk{chunk}"), reqs.len() as f64 / bat_s, bat_calls);
        println!(
            "serve {} mixed-device requests, chunk {chunk:>2}: \
             batched drain {:.1} ms ({:.1} plans/s, {} backend calls) vs \
             sequential {:.1} ms ({:.1} plans/s, {} calls) -> speedup {:.2}x",
            reqs.len(),
            bat_s * 1e3,
            reqs.len() as f64 / bat_s,
            bat_calls,
            seq_s * 1e3,
            reqs.len() as f64 / seq_s,
            seq_calls,
            seq_s / bat_s,
        );
    }

    // pipelined drain (sessions on the runtime worker pool, double-
    // buffered chunk fills) vs blocking drain, across pool sizes. Plans
    // are bit-identical (tests/serve.rs pins it); only the overlap wins.
    for workers in [1usize, 2, 4] {
        let rtw = Arc::new(Runtime::open_default().expect("runtime").with_workers(workers));
        let drain = |pipelined: bool| {
            let mut svc = PlanService::new(
                &rtw,
                Box::new(DreamShardPlacer::from_agent(&rtw, &agent)),
                ServeConfig { capacity: reqs.len(), chunk: 16, ..ServeConfig::default() },
            );
            for r in &reqs {
                svc.submit(*r).unwrap();
            }
            let t0 = Instant::now(); // lint: allow(clock-transitive) — wall-clock timing section is what this bench measures
            let done = if pipelined { svc.drain().unwrap() } else { svc.drain_blocking().unwrap() };
            assert_eq!(done.len(), reqs.len());
            t0.elapsed().as_secs_f64()
        };
        drain(true); // warm
        let blk_s = drain(false);
        let calls0 = rtw.run_count();
        let pipe_s = drain(true);
        emit_json(
            &format!("serve_pipelined_w{workers}"),
            reqs.len() as f64 / pipe_s,
            rtw.run_count() - calls0,
        );
        println!(
            "pipelined drain, {workers} worker(s): blocking {:.1} ms ({:.1} plans/s) vs \
             pipelined {:.1} ms ({:.1} plans/s) -> overlap win {:.2}x",
            blk_s * 1e3,
            reqs.len() as f64 / blk_s,
            pipe_s * 1e3,
            reqs.len() as f64 / pipe_s,
            blk_s / pipe_s,
        );
    }

    // sharded front end vs one shared FIFO on the mixed 2/4/8/128-device
    // workload: the single service interleaves d8s48 and d128s16 chunks
    // through one queue, while the front end routes each variant to its
    // own PlanService and drains both on their own threads against the
    // same worker pool. Plans and call budgets are bit-identical to the
    // sequential per-variant drains (tests/sharded.rs pins it).
    let mixed = synthetic_arrivals(&pool, &WorkloadCfg {
        n_requests: 64,
        device_mix: vec![2, 4, 8, 128],
        min_tables: 10,
        max_tables: 24,
        mean_gap_ms: 1.0,
        seed: 7,
        ..WorkloadCfg::default()
    });
    for workers in [2usize, 4] {
        let rtw = Arc::new(Runtime::open_default().expect("runtime").with_workers(workers));
        let mixed_reqs: Vec<PlacementRequest> = mixed
            .iter()
            .map(|a| PlacementRequest::for_runtime(&rtw, &ds, &a.task, &sim).unwrap())
            .collect();
        let single = || {
            let mut svc = PlanService::new(
                &rtw,
                Box::new(DreamShardPlacer::from_agent(&rtw, &agent)),
                ServeConfig { capacity: mixed_reqs.len(), chunk: 16, ..ServeConfig::default() },
            );
            for r in &mixed_reqs {
                svc.submit(*r).unwrap();
            }
            let t0 = Instant::now(); // lint: allow(clock-transitive) — wall-clock timing section is what this bench measures
            let done = svc.drain().unwrap();
            assert_eq!(done.len(), mixed_reqs.len());
            t0.elapsed().as_secs_f64()
        };
        let sharded = || {
            let factory = {
                let rtw = Arc::clone(&rtw);
                let agent = &agent;
                move || Ok(Box::new(DreamShardPlacer::from_agent(&rtw, agent)) as Box<dyn Placer>)
            };
            let mut front = ShardedFrontEnd::new(&rtw, factory, ShardConfig {
                per_shard: ServeConfig {
                    capacity: mixed_reqs.len(),
                    chunk: 16,
                    ..ServeConfig::default()
                },
                global_cap: mixed_reqs.len(),
            })
            .unwrap();
            for r in &mixed_reqs {
                front.submit(*r).unwrap();
            }
            let t0 = Instant::now(); // lint: allow(clock-transitive) — wall-clock timing section is what this bench measures
            let done = front.drain().unwrap();
            assert_eq!(done.len(), mixed_reqs.len());
            t0.elapsed().as_secs_f64()
        };
        single(); // warm
        sharded();
        let single_s = single();
        let calls0 = rtw.run_count();
        let sharded_s = sharded();
        emit_json(
            &format!("serve_sharded_w{workers}"),
            mixed.len() as f64 / sharded_s,
            rtw.run_count() - calls0,
        );
        println!(
            "sharded front end, {workers} worker(s), 2/4/8/128 mix: single FIFO {:.1} ms \
             ({:.1} plans/s) vs sharded {:.1} ms ({:.1} plans/s) -> {:.2}x",
            single_s * 1e3,
            mixed.len() as f64 / single_s,
            sharded_s * 1e3,
            mixed.len() as f64 / sharded_s,
            single_s / sharded_s,
        );
    }

    // closed-loop controller vs static knobs on an overdriven replay: a
    // TestClock turns arrival gaps (and measured planning wall time)
    // into virtual time, so the virtual tail latency and shed counts
    // compare policies — latency-targeted admission, chunk sizing, and
    // drain scheduling — rather than host noise.
    let overdriven = synthetic_arrivals(&pool, &WorkloadCfg {
        n_requests: 64,
        device_mix: vec![2, 4, 8, 128],
        min_tables: 10,
        max_tables: 24,
        mean_gap_ms: 0.5,
        closed_loop: true,
        batch_pct: 25,
        seed: 7,
    });
    let rtw = Arc::new(Runtime::open_default().expect("runtime").with_workers(2));
    let replay = |controlled: bool| {
        let clock = Arc::new(TestClock::new());
        let factory = {
            let rtw = Arc::clone(&rtw);
            let agent = &agent;
            move || Ok(Box::new(DreamShardPlacer::from_agent(&rtw, agent)) as Box<dyn Placer>)
        };
        let mut front = ShardedFrontEnd::with_clock(
            &rtw,
            factory,
            ShardConfig {
                per_shard: ServeConfig { capacity: 16, chunk: 8, ..ServeConfig::default() },
                global_cap: 24,
            },
            Arc::clone(&clock) as Arc<dyn Clock>,
        )
        .unwrap();
        let mut ctl = Controller::new(ControlConfig { target_ms: 30.0, ..Default::default() });
        for burst in overdriven.chunks(8) {
            for a in burst {
                clock.advance_ms(a.at_ms);
                let req = PlacementRequest::for_runtime(&rtw, &ds, &a.task, &sim).unwrap();
                let _ = front.submit_slo(req, a.class, None).unwrap(); // None = shed
            }
            let t0 = Instant::now(); // lint: allow(clock-transitive) — wall-clock timing section is what this bench measures
            if controlled {
                ctl.tick(&mut front).unwrap();
            } else if front.shards().any(|s| s.queued >= s.chunk) {
                front.drain().unwrap();
            }
            clock.advance_ms(t0.elapsed().as_secs_f64() * 1e3);
        }
        let mut guard = 0;
        while !front.is_empty() && guard < 64 {
            if controlled {
                clock.advance_ms(ctl.config().max_idle_ms);
                ctl.tick(&mut front).unwrap();
            } else {
                front.drain().unwrap();
            }
            guard += 1;
        }
        let fs = front.stats();
        let shed = fs.shed_global + fs.aggregate.rejected;
        let shed_interactive =
            (fs.shed_global - fs.shed_global_batch) + (fs.aggregate.rejected - fs.aggregate.shed_batch);
        (fs.aggregate.p95_queue_ms(), shed, shed_interactive)
    };
    replay(true); // warm
    let (static_p95, static_shed, static_int) = replay(false);
    let (ctl_p95, ctl_shed, ctl_int) = replay(true);
    println!(
        "closed-loop 2/4/8/128 mix, 25% batch: static knobs p95 {static_p95:.1} ms, \
         {static_shed} shed ({static_int} interactive) vs controller p95 {ctl_p95:.1} ms, \
         {ctl_shed} shed ({ctl_int} interactive)",
    );
}
