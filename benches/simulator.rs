//! Simulator micro-benchmarks: evaluation throughput at several task
//! scales (the simulator is on the data-collection path and inside the
//! RNN baseline's reward loop, so it must stay in the microsecond range).
use dreamshard::bench::common::emit_json;
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::tables::{gen_dlrm, gen_prod, sample_tasks, split_pools};
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    f();
    let t0 = Instant::now(); // lint: allow(clock-transitive) — wall-clock timing section is what this bench measures
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name}: {:.1} us/call", per * 1e6);
    per
}

fn main() {
    for (n_tables, n_dev) in [(20usize, 4usize), (50, 4), (200, 8), (960, 128)] {
        let ds = if n_dev > 8 { gen_prod(1024, 77) } else { gen_dlrm(856, 42) };
        let (pool, _) = split_pools(&ds, 1);
        let task = sample_tasks(&pool, n_tables.min(pool.len()), n_dev, 1, 7).remove(0);
        let sim = Simulator::new(SimConfig::default());
        let placement: Vec<usize> = (0..task.n_tables()).map(|i| i % n_dev).collect();
        let per = bench(
            &format!("evaluate {n_tables} tables x {n_dev} devices"),
            200,
            || {
                sim.evaluate(&ds, &task, &placement);
            },
        );
        // pure-CPU bench: the simulator never touches the runtime
        emit_json(&format!("sim_evaluate_{n_tables}x{n_dev}"), 1.0 / per, 0);
    }
}
