//! End-to-end placement benchmarks: inference latency per task size (the
//! paper's headline "hundreds of tables in less than a second", Fig. 8)
//! and one full Algorithm-1 training iteration.
use dreamshard::bench::common::{make_suite, Which};
use dreamshard::coordinator::{DreamShard, TrainCfg};
use dreamshard::runtime::Runtime;
use dreamshard::util::Rng;
use std::time::Instant;

fn main() {
    let rt = Runtime::open_default().expect("runtime");
    let mut rng = Rng::new(0);
    for (n, d) in [(10usize, 4usize), (50, 4), (100, 4), (200, 8)] {
        let suite = make_suite(Which::Dlrm, n, d, 2, 7);
        let agent = DreamShard::new(&rt, d, TrainCfg::default(), &mut rng).unwrap();
        let task = &suite.test[0];
        agent.place(&rt, &suite.sim, &suite.ds, task).unwrap(); // warm
        let t0 = Instant::now();
        let reps = 5;
        for _ in 0..reps {
            agent.place(&rt, &suite.sim, &suite.ds, task).unwrap();
        }
        println!(
            "place {n} tables x {d} devices: {:.1} ms",
            t0.elapsed().as_secs_f64() / reps as f64 * 1e3
        );
    }
    // one full training iteration at the paper's default budget
    let suite = make_suite(Which::Dlrm, 50, 4, 4, 7);
    let mut agent = DreamShard::new(&rt, 4, TrainCfg::default(), &mut rng).unwrap();
    let t0 = Instant::now();
    agent
        .train_iteration(&rt, &suite.sim, &suite.ds, &suite.train, 0, false, &mut rng)
        .unwrap();
    println!(
        "one Algorithm-1 iteration (paper budget, DLRM-50 (4)): {:.1} s",
        t0.elapsed().as_secs_f64()
    );
}
