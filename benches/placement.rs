//! End-to-end placement benchmarks: inference latency per task size (the
//! paper's headline "hundreds of tables in less than a second", Fig. 8),
//! lane-batched vs sequential multi-task planning through the `Placer`
//! facade, and one full Algorithm-1 training iteration.
use dreamshard::bench::common::{emit_json, make_suite, Which};
use dreamshard::coordinator::{DreamShard, TrainCfg};
use dreamshard::placer::{DreamShardPlacer, Placer, PlacementRequest};
use dreamshard::runtime::Runtime;
use dreamshard::util::Rng;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let rt = Arc::new(Runtime::open_default().expect("runtime"));
    let mut rng = Rng::new(0);
    for (n, d) in [(10usize, 4usize), (50, 4), (100, 4), (200, 8)] {
        let suite = make_suite(Which::Dlrm, n, d, 2, 7);
        let agent = DreamShard::new(&rt, d, TrainCfg::default(), &mut rng).unwrap();
        let task = &suite.test[0];
        agent.place(&rt, &suite.sim, &suite.ds, task).unwrap(); // warm
        let calls0 = rt.run_count();
        let t0 = Instant::now(); // lint: allow(clock-transitive) — wall-clock timing section is what this bench measures
        let reps = 5;
        for _ in 0..reps {
            agent.place(&rt, &suite.sim, &suite.ds, task).unwrap();
        }
        let per = t0.elapsed().as_secs_f64() / reps as f64;
        println!("place {n} tables x {d} devices: {:.1} ms", per * 1e3);
        emit_json(&format!("place_{n}x{d}"), 1.0 / per, rt.run_count() - calls0);
    }

    // multi-task planning: sequential episodes vs lane-batched place_many
    // (identical plans — see tests/placer_api.rs — different wall-clock)
    let suite = make_suite(Which::Dlrm, 50, 4, 16, 11);
    let agent = DreamShard::new(&rt, 4, TrainCfg::default(), &mut rng).unwrap();
    let mut placer = DreamShardPlacer::from_agent(&rt, &agent);
    let reqs: Vec<PlacementRequest> = suite
        .train
        .iter()
        .map(|t| PlacementRequest::for_runtime(&rt, &suite.ds, t, &suite.sim).unwrap())
        .collect();
    placer.place_many(&reqs).unwrap(); // warm
    let reps = 3;
    let t0 = Instant::now(); // lint: allow(clock-transitive) — wall-clock timing section is what this bench measures
    for _ in 0..reps {
        for r in &reqs {
            placer.place(r).unwrap();
        }
    }
    let seq_s = t0.elapsed().as_secs_f64() / reps as f64;
    let calls0 = rt.run_count();
    let t0 = Instant::now(); // lint: allow(clock-transitive) — wall-clock timing section is what this bench measures
    for _ in 0..reps {
        placer.place_many(&reqs).unwrap();
    }
    let batched_s = t0.elapsed().as_secs_f64() / reps as f64;
    emit_json("plan_lane_batched", reqs.len() as f64 / batched_s, rt.run_count() - calls0);
    println!(
        "plan {} tasks (50 tables x 4 devices): sequential {:.1} ms ({:.1} tasks/s), \
         lane-batched {:.1} ms ({:.1} tasks/s), speedup {:.2}x",
        reqs.len(),
        seq_s * 1e3,
        reqs.len() as f64 / seq_s,
        batched_s * 1e3,
        reqs.len() as f64 / batched_s,
        seq_s / batched_s
    );

    // one full training iteration at the paper's default budget
    let suite = make_suite(Which::Dlrm, 50, 4, 4, 7);
    let mut agent = DreamShard::new(&rt, 4, TrainCfg::default(), &mut rng).unwrap();
    let t0 = Instant::now(); // lint: allow(clock-transitive) — wall-clock timing section is what this bench measures
    agent
        .train_iteration(&rt, &suite.sim, &suite.ds, &suite.train, 0, false, &mut rng)
        .unwrap();
    println!(
        "one Algorithm-1 iteration (paper budget, DLRM-50 (4)): {:.1} s",
        t0.elapsed().as_secs_f64()
    );
}
