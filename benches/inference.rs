//! Micro-bench: per-artifact PJRT call latency on the placement hot path,
//! plus two kernel-level sections for the blocked reference kernels: the
//! blocked-vs-naive linear chain (the table-MLP shape) and the intra-op
//! row split of the chunk-concatenated `[N, F]` `table_cost` batch at
//! widths 1/2/4. Headline numbers are also emitted as `BENCH_JSON` lines
//! (see `bench::common::emit_json`).
//! (hand-rolled harness: the offline dependency closure has no criterion)
use dreamshard::bench::common::{emit_json, make_suite, Which};
use dreamshard::coordinator::{CostNet, DreamShard, PolicyNet, TrainCfg, Variant};
use dreamshard::runtime::reference::math::{self, Lin};
use dreamshard::runtime::reference::reference_manifest;
use dreamshard::runtime::{ReferenceBackend, Runtime, TensorF32, Value};
use dreamshard::tables::NUM_FEATURES;
use dreamshard::util::Rng;
use std::time::Instant;

/// Times `f` over `iters` calls (after one warmup); returns secs/call.
fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // warmup
    f();
    let t0 = Instant::now(); // lint: allow(clock-transitive) — wall-clock timing section is what this bench measures
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name}: {:.2} ms/call", per * 1e3);
    per
}

fn main() {
    let rt = Runtime::open_default().expect("runtime");
    let mut rng = Rng::new(0);
    let var = Variant::for_devices(&rt, 4).unwrap();
    let cost = CostNet::new(&rt, &mut rng).unwrap();
    let policy = PolicyNet::new(&rt, &mut rng).unwrap();
    let (e, d, s, f) = (var.e, var.d, var.s, NUM_FEATURES);
    let feats = TensorF32::zeros(&[e, d, s, f]);
    let mask = TensorF32::ones(&[e, d, s]);
    let dmask = TensorF32::ones(&[e, d]);
    let calls0 = rt.run_count();
    let per = bench("cost_fwd (E=16,D=4,S=48)", 50, || {
        cost.predict_tensors(&rt, &var, &feats, &mask, &dmask, 16).unwrap();
    });
    emit_json("cost_fwd", 1.0 / per, rt.run_count() - calls0);
    let q = TensorF32::zeros(&[e, d, 3]);
    let cur = TensorF32::zeros(&[e, f]);
    let legal = TensorF32::ones(&[e, d]);
    let calls0 = rt.run_count();
    let per = bench("policy_fwd", 50, || {
        policy.logits(&rt, &var, &feats, &mask, &q, &cur, &legal, 16).unwrap();
    });
    emit_json("policy_fwd", 1.0 / per, rt.run_count() - calls0);
    // cost_train
    let mut cost2 = cost.clone();
    let bf = TensorF32::zeros(&[var.b_cost, d, s, f]);
    let bm = TensorF32::ones(&[var.b_cost, d, s]);
    let bd = TensorF32::ones(&[var.b_cost, d]);
    let bq = TensorF32::zeros(&[var.b_cost, d, 3]);
    let bc = TensorF32::zeros(&[var.b_cost]);
    let calls0 = rt.run_count();
    let per = bench("cost_train (B=64)", 30, || {
        cost2.train_batch(&rt, &var, &bf, &bm, &bd, &bq, &bc, 5e-4).unwrap();
    });
    emit_json("cost_train", 1.0 / per, rt.run_count() - calls0);
    // policy_train b512
    let steps: Vec<dreamshard::coordinator::StepRec> = (0..500)
        .map(|_| dreamshard::coordinator::StepRec {
            feats: vec![0.0; d * s * f],
            mask: vec![1.0; d * s],
            q: vec![0.0; d * 3],
            cur: vec![0.0; f],
            legal: vec![1.0; d],
            action: 0,
        })
        .collect();
    let adv = vec![0.0f32; 500];
    let mut pol2 = policy.clone();
    let calls0 = rt.run_count();
    let per = bench("policy_train (500 steps -> b512)", 10, || {
        pol2.train_steps(&rt, &var, &steps, &adv, 5e-4).unwrap();
    });
    emit_json("policy_train", 1.0 / per, rt.run_count() - calls0);
    // full placement inference
    let suite = make_suite(Which::Dlrm, 50, 4, 2, 7);
    let agent = {
        let mut rng = Rng::new(1);
        let mut a = DreamShard::new(&rt, 4, TrainCfg::default(), &mut rng).unwrap();
        a.cost = cost;
        a.policy = policy;
        a
    };
    let calls0 = rt.run_count();
    let per = bench("place (50 tables, 4 devices)", 5, || {
        agent.place(&rt, &suite.sim, &suite.ds, &suite.test[0]).unwrap();
    });
    emit_json("place_50x4", 1.0 / per, rt.run_count() - calls0);

    // blocked vs naive reference kernels on the table-MLP chain
    // [256, F] -> 128 -> 32 (the kept `_naive` kernels are the
    // bit-identity oracles — see tests/kernels.rs)
    let mut krng = Rng::new(7);
    let rows = 256usize;
    let l1 = Lin { w: 0, b: NUM_FEATURES * 128, n_in: NUM_FEATURES, n_out: 128 };
    let l2 = Lin { w: 0, b: 128 * 32, n_in: 128, n_out: 32 };
    let th1 = math::rand_vec(l1.b + l1.n_out, 0.5, &mut krng);
    let th2 = math::rand_vec(l2.b + l2.n_out, 0.5, &mut krng);
    let x = math::rand_vec(rows * NUM_FEATURES, 1.0, &mut krng);
    let naive_per = bench("linear naive ([256,F]->128->32)", 400, || {
        let h = math::linear_fwd_naive(&th1, l1, &x, rows, true);
        let y = math::linear_fwd_naive(&th2, l2, &h, rows, false);
        std::hint::black_box(&y);
    });
    let blocked_per = bench("linear blocked ([256,F]->128->32)", 400, || {
        let h = math::linear_fwd(&th1, l1, &x, rows, true);
        let y = math::linear_fwd(&th2, l2, &h, rows, false);
        std::hint::black_box(&y);
    });
    println!("blocked vs naive linear chain: {:.2}x", naive_per / blocked_per);
    emit_json("linear_naive_256xF", 1.0 / naive_per, 0);
    emit_json("linear_blocked_256xF", 1.0 / blocked_per, 0);

    // intra-op row split of one large concatenated `table_cost` batch:
    // bit-identical across widths (tests/kernels.rs pins it), so only
    // the wall clock may move. One submit stays ONE counted dispatch.
    let n = 1024usize;
    let mut serial_per = f64::NAN;
    for intra in [1usize, 2, 4] {
        let rtw = Runtime::with_backend(
            reference_manifest(),
            Box::new(ReferenceBackend::with_intra_op(intra)),
        );
        let mut rng2 = Rng::new(5);
        let theta = rtw.init_params("cost", &mut rng2).unwrap();
        let fdim = rtw.manifest.consts["F"] as usize;
        let mut feats = TensorF32::zeros(&[n, fdim]);
        for v in feats.data.iter_mut() {
            *v = rng2.uniform(0.0, 1.0) as f32;
        }
        let inputs: Vec<Value> = vec![
            TensorF32::from_vec(theta, &[rtw.manifest.params["cost"].total]).value(),
            feats.value(),
            TensorF32::ones(&[fdim]).value(),
        ];
        let calls0 = rtw.run_count();
        let per = bench(&format!("table_cost [{n}, F] intra={intra}"), 50, || {
            rtw.run("table_cost", &inputs).unwrap();
        });
        let calls = rtw.run_count() - calls0;
        if intra == 1 {
            serial_per = per;
        } else {
            println!("  table_cost intra={intra}: {:.2}x vs serial", serial_per / per);
        }
        emit_json(&format!("table_cost_{n}_intra{intra}"), 1.0 / per, calls);
    }
}
