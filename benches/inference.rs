//! Micro-bench: per-artifact PJRT call latency on the placement hot path.
//! (hand-rolled harness: the offline dependency closure has no criterion)
use dreamshard::bench::common::{make_suite, Which};
use dreamshard::coordinator::{CostNet, DreamShard, PolicyNet, TrainCfg, Variant};
use dreamshard::runtime::{Runtime, TensorF32};
use dreamshard::tables::NUM_FEATURES;
use dreamshard::util::Rng;
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // warmup
    f();
    let t0 = Instant::now(); // lint: allow(clock-transitive) — wall-clock timing section is what this bench measures
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name}: {:.2} ms/call", per * 1e3);
}

fn main() {
    let rt = Runtime::open_default().expect("runtime");
    let mut rng = Rng::new(0);
    let var = Variant::for_devices(&rt, 4).unwrap();
    let cost = CostNet::new(&rt, &mut rng).unwrap();
    let policy = PolicyNet::new(&rt, &mut rng).unwrap();
    let (e, d, s, f) = (var.e, var.d, var.s, NUM_FEATURES);
    let feats = TensorF32::zeros(&[e, d, s, f]);
    let mask = TensorF32::ones(&[e, d, s]);
    let dmask = TensorF32::ones(&[e, d]);
    bench("cost_fwd (E=16,D=4,S=48)", 50, || {
        cost.predict_tensors(&rt, &var, &feats, &mask, &dmask, 16).unwrap();
    });
    let q = TensorF32::zeros(&[e, d, 3]);
    let cur = TensorF32::zeros(&[e, f]);
    let legal = TensorF32::ones(&[e, d]);
    bench("policy_fwd", 50, || {
        policy.logits(&rt, &var, &feats, &mask, &q, &cur, &legal, 16).unwrap();
    });
    // cost_train
    let mut cost2 = cost.clone();
    let bf = TensorF32::zeros(&[var.b_cost, d, s, f]);
    let bm = TensorF32::ones(&[var.b_cost, d, s]);
    let bd = TensorF32::ones(&[var.b_cost, d]);
    let bq = TensorF32::zeros(&[var.b_cost, d, 3]);
    let bc = TensorF32::zeros(&[var.b_cost]);
    bench("cost_train (B=64)", 30, || {
        cost2.train_batch(&rt, &var, &bf, &bm, &bd, &bq, &bc, 5e-4).unwrap();
    });
    // policy_train b512
    let steps: Vec<dreamshard::coordinator::StepRec> = (0..500)
        .map(|_| dreamshard::coordinator::StepRec {
            feats: vec![0.0; d * s * f],
            mask: vec![1.0; d * s],
            q: vec![0.0; d * 3],
            cur: vec![0.0; f],
            legal: vec![1.0; d],
            action: 0,
        })
        .collect();
    let adv = vec![0.0f32; 500];
    let mut pol2 = policy.clone();
    bench("policy_train (500 steps -> b512)", 10, || {
        pol2.train_steps(&rt, &var, &steps, &adv, 5e-4).unwrap();
    });
    // full placement inference
    let suite = make_suite(Which::Dlrm, 50, 4, 2, 7);
    let agent = {
        let mut rng = Rng::new(1);
        let mut a = DreamShard::new(&rt, 4, TrainCfg::default(), &mut rng).unwrap();
        a.cost = cost;
        a.policy = policy;
        a
    };
    bench("place (50 tables, 4 devices)", 5, || {
        agent.place(&rt, &suite.sim, &suite.ds, &suite.test[0]).unwrap();
    });
}
