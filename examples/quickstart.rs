//! Quickstart: train DreamShard on small DLRM tasks, place a task with
//! unseen tables, and compare against the expert baselines.
//!
//!     cargo run --release --example quickstart
//!
//! Runs on the pure-Rust reference backend by default; `make artifacts`
//! plus `--features xla` switches to the PJRT/XLA backend.

use dreamshard::baselines::{greedy_placement, random_placement, ALL_EXPERTS};
use dreamshard::coordinator::{DreamShard, TrainCfg};
use dreamshard::runtime::Runtime;
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::tables::{gen_dlrm, sample_tasks, split_pools};
use dreamshard::util::Rng;

fn main() -> dreamshard::Result<()> {
    // 1. open the runtime (reference backend unless XLA artifacts exist)
    let rt = Runtime::open_default()?;

    // 2. a synthetic DLRM table pool and disjoint train/test tasks
    let ds = gen_dlrm(856, 42);
    let (pool_tr, pool_te) = split_pools(&ds, 1);
    let train_tasks = sample_tasks(&pool_tr, 30, 4, 20, 2);
    let test_task = sample_tasks(&pool_te, 30, 4, 1, 3).remove(0);

    // 3. the simulated 4-GPU cluster (the "hardware" of this repo)
    let sim = Simulator::new(SimConfig::default());

    // 4. train (Algorithm 1): cost net + policy net on the estimated MDP
    let mut rng = Rng::new(0);
    let mut agent = DreamShard::new(&rt, 4, TrainCfg::fast(), &mut rng)?;
    println!("training on {} tasks ...", train_tasks.len());
    agent.train(&rt, &sim, &ds, &train_tasks, &mut rng)?;
    for st in &agent.log {
        println!(
            "  iter {}: collected {:.1} ms | cost-loss {:.2} | {:.1}s",
            st.iter, st.collected_mean_cost, st.cost_loss, st.wall_s
        );
    }

    // 5. place a task of UNSEEN tables (Algorithm 2 — no simulator costs)
    let placement = agent.place(&rt, &sim, &ds, &test_task)?;
    let eval = sim.evaluate(&ds, &test_task, &placement);
    println!("\n{}", sim.render_trace(&eval, "DreamShard"));

    // 6. compare with the baselines
    let mut rows = vec![("random".to_string(), {
        let p = random_placement(&ds, &test_task, &sim, &mut rng);
        sim.evaluate(&ds, &test_task, &p).latency
    })];
    for e in ALL_EXPERTS {
        let p = greedy_placement(&ds, &test_task, &sim, e);
        rows.push((e.name().to_string(), sim.evaluate(&ds, &test_task, &p).latency));
    }
    rows.push(("DreamShard".to_string(), eval.latency));
    println!("strategy            cost (ms)");
    for (name, ms) in rows {
        println!("{name:<18}  {ms:>8.2}");
    }
    Ok(())
}
