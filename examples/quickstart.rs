//! Quickstart: train DreamShard on small DLRM tasks through the `placer`
//! facade, plan a task with unseen tables, and compare every registered
//! baseline on the *same* `PlacementRequest`.
//!
//!     cargo run --release --example quickstart
//!
//! Runs on the pure-Rust reference backend by default; `make artifacts`
//! plus `--features xla` switches to the PJRT/XLA backend.

use dreamshard::coordinator::TrainCfg;
use dreamshard::placer::{self, FitRequest, Placer, PlacementRequest};
use dreamshard::runtime::Runtime;
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::tables::{gen_dlrm, sample_tasks, split_pools};

fn main() -> dreamshard::Result<()> {
    // 1. open the runtime (reference backend unless XLA artifacts exist);
    //    placers share it through an Arc
    let rt = std::sync::Arc::new(Runtime::open_default()?);

    // 2. a synthetic DLRM table pool and disjoint train/test tasks
    let ds = gen_dlrm(856, 42);
    let (pool_tr, pool_te) = split_pools(&ds, 1);
    let train_tasks = sample_tasks(&pool_tr, 30, 4, 20, 2);
    let test_task = sample_tasks(&pool_te, 30, 4, 1, 3).remove(0);

    // 3. the simulated 4-GPU cluster (the "hardware" of this repo)
    let sim = Simulator::new(SimConfig::default());

    // 4. every strategy is a `Placer` picked by name; "dreamshard" comes
    //    out of the registry untrained, so fit it (Algorithm 1)
    let mut agent = placer::by_name(&rt, "dreamshard")?;
    println!("training on {} tasks ...", train_tasks.len());
    agent.fit(&FitRequest {
        ds: &ds,
        tasks: &train_tasks,
        sim: &sim,
        cfg: TrainCfg::fast(),
        seed: 0,
        verbose: true,
    })?;

    // 5. plan a task of UNSEEN tables (Algorithm 2 — no simulator costs);
    //    the request carries the task plus the shared legality knobs
    let req = PlacementRequest::for_runtime(&rt, &ds, &test_task, &sim)?;
    let plan = agent.place(&req)?;
    println!("\n{}", sim.render_trace(&plan.eval, "DreamShard"));

    // 6. the identical request through every non-learned baseline
    println!("strategy            cost (ms)");
    for name in placer::PLACER_NAMES {
        let mut p = placer::by_name(&rt, name)?;
        if p.needs_fit() {
            continue; // learned strategies need their own training run
        }
        let b = p.place(&req)?;
        println!("{:<18}  {:>8.2}", b.strategy, b.eval.latency);
    }
    println!("{:<18}  {:>8.2}", plan.strategy, plan.eval.latency);
    Ok(())
}
