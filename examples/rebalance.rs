//! Incremental re-placement in a dozen lines: plan an embedding-table
//! task on 4 devices, lose one, and repair the plan onto the surviving
//! 3 with a budgeted [`Placer::replace`] instead of re-planning from
//! scratch.
//!
//!     cargo run --release --example rebalance
//!
//! `replace` keeps every table where it was unless feasibility (the
//! lost device, memory caps) forces a move or the migration budget
//! allows a balance-restoring one — so the fleet copies a handful of
//! tables' weights instead of reshuffling everything. The same seam
//! runs through the whole stack: every registered placer answers
//! `replace` (the `dreamshard` policy re-rolls its MDP warm-started
//! from the previous plan), and `PlanService::rebalance` /
//! `ShardedFrontEnd::rebalance` drain whole batches of it — see
//! `dreamshard serve-sim --rebalance` and `benches/rebalance.rs` for
//! the fleet-scale comparison.

use std::sync::Arc;

use dreamshard::placer::{self, MigrationBudget, Placer, PlacementRequest};
use dreamshard::runtime::Runtime;
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::tables::{gen_dlrm, sample_tasks, split_pools, Task};

fn main() -> dreamshard::Result<()> {
    let rt = Arc::new(Runtime::open_default()?);
    let ds = gen_dlrm(200, 7);
    let (pool, _) = split_pools(&ds, 1);
    let sim = Simulator::new(SimConfig::default());
    let task = sample_tasks(&pool, 24, 4, 1, 3).remove(0);

    // day 1: a healthy 4-device fleet
    let mut placer = placer::by_name(&rt, "greedy:size-lookup")?;
    let req = PlacementRequest::for_runtime(&rt, &ds, &task, &sim)?;
    let plan = placer.place(&req)?;
    println!("{}", sim.render_trace(&plan.eval, "day 1: 24 tables on 4 devices"));

    // day 2: device 3 dies. Its tables are forced moves; at most 2 more
    // tables may move to restore balance (the migration budget).
    let smaller = Task { table_ids: task.table_ids.clone(), n_devices: 3 };
    let req = PlacementRequest::for_runtime(&rt, &ds, &smaller, &sim)?
        .with_migration(MigrationBudget::moves(2));
    let repaired = placer.replace(&plan, &req)?;
    let stayed = plan
        .placement
        .iter()
        .zip(&repaired.placement)
        .filter(|(a, b)| a == b)
        .count();
    println!(
        "{}",
        sim.render_trace(&repaired.eval, "day 2: device 3 lost, budgeted replace")
    );
    println!(
        "replace moved {} tables ({:.2} ms of weight migration); {stayed} stayed put\n",
        repaired.eval.moved_tables, repaired.eval.migration_ms,
    );

    // the alternative: forget the old plan and re-pack from scratch —
    // then pay to move every table that landed somewhere new
    let scratch = placer.place(&req)?;
    let bill = sim.evaluate_migration(&ds, &smaller, &plan.placement, &scratch.placement);
    println!(
        "scratch re-plan: {:.2} ms latency (vs {:.2} ms) but {} tables moved \
         ({:.2} ms migration) -> total {:.2} ms vs replace's {:.2} ms",
        bill.latency,
        repaired.eval.latency,
        bill.moved_tables,
        bill.migration_ms,
        bill.total_ms(),
        repaired.eval.total_ms(),
    );
    Ok(())
}
