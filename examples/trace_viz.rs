//! Trace visualization (paper Fig. 1 / Figs. 23-28): render the four-stage
//! embedding pipeline per device for random vs each expert strategy on a
//! DLRM-50 (4) task — every strategy pulled from the placer registry and
//! planning the same `PlacementRequest`. No training required.
//!
//!     cargo run --release --example trace_viz [n_tables] [n_devices]

use dreamshard::placer::{self, Placer, PlacementRequest};
use dreamshard::runtime::Runtime;
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::tables::{gen_dlrm, sample_tasks, split_pools};

fn main() -> dreamshard::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_tables: usize = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(50);
    let n_devices: usize = args.get(2).and_then(|v| v.parse().ok()).unwrap_or(4);

    let ds = gen_dlrm(856, 42);
    let (pool, _) = split_pools(&ds, 1);
    let task = sample_tasks(&pool, n_tables, n_devices, 1, 7).remove(0);
    let sim = Simulator::new(SimConfig::default());
    let rt = std::sync::Arc::new(Runtime::open_default()?);
    // variant slot cap when the grid covers this device count; the
    // heuristics render fine uncapped for exotic counts (e.g. 200 GPUs)
    let req = PlacementRequest::for_runtime(&rt, &ds, &task, &sim)
        .unwrap_or_else(|_| PlacementRequest::new(&ds, &task, &sim));

    println!(
        "task: {} tables on {} devices (F=fwd comp, f=fwd comm, b=bwd comm, B=bwd comp)\n",
        n_tables, n_devices
    );
    for name in placer::PLACER_NAMES {
        let mut p = placer::by_name(&rt, name)?;
        if p.needs_fit() {
            continue; // heuristics only — this demo never trains
        }
        let plan = p.place(&req)?;
        print!("{}", sim.render_trace(&plan.eval, &plan.strategy));
        println!();
    }
    Ok(())
}
