//! Trace visualization (paper Fig. 1 / Figs. 23-28): render the four-stage
//! embedding pipeline per device for random vs each expert strategy on a
//! DLRM-50 (4) task. Pure substrate demo — no training required.
//!
//!     cargo run --release --example trace_viz [n_tables] [n_devices]

use dreamshard::baselines::{greedy_placement, random_placement, ALL_EXPERTS};
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::tables::{gen_dlrm, sample_tasks, split_pools};
use dreamshard::util::Rng;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let n_tables: usize = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(50);
    let n_devices: usize = args.get(2).and_then(|v| v.parse().ok()).unwrap_or(4);

    let ds = gen_dlrm(856, 42);
    let (pool, _) = split_pools(&ds, 1);
    let task = sample_tasks(&pool, n_tables, n_devices, 1, 7).remove(0);
    let sim = Simulator::new(SimConfig::default());
    let mut rng = Rng::new(0);

    println!("task: {} tables on {} devices (F=fwd comp, f=fwd comm, b=bwd comm, B=bwd comp)\n", n_tables, n_devices);
    let p = random_placement(&ds, &task, &sim, &mut rng);
    print!("{}", sim.render_trace(&sim.evaluate(&ds, &task, &p), "random"));
    println!();
    for e in ALL_EXPERTS {
        let p = greedy_placement(&ds, &task, &sim, e);
        print!("{}", sim.render_trace(&sim.evaluate(&ds, &task, &p), e.name()));
        println!();
    }
}
