//! Serving front end in a dozen lines: feed an open-loop mix of
//! 2/4/8-device placement requests through a bounded [`PlanService`]
//! queue and drain it in lane-batched chunks.
//!
//!     cargo run --release --example serve_queue
//!
//! The service wraps *any* registered placer; here the untrained
//! DreamShard agent (deterministic random-init weights) so the run is
//! quick — swap in a fitted one exactly as `examples/quickstart.rs`
//! trains it. Watch the backend-call counter: a drained chunk shares one
//! fused `mdp_step` call per MDP step across all its lanes and orders
//! every task with one concatenated `table_cost` pass, so serving beats
//! per-request planning on calls as well as wall-clock. The drain itself
//! is pipelined: while one chunk's fused call executes on the runtime's
//! worker pool, the next chunk's feature tensors are filled
//! (`PlanService::drain` — `drain_blocking` is the serial comparison).
//!
//! The second half runs the same traffic — now with 128-device requests
//! mixed in — through a `ShardedFrontEnd`: one `PlanService` per serving
//! variant behind a single submit API, each shard draining on its own
//! thread, so the heavyweight 128-device chunks never stall the
//! small-device stream at the head of one FIFO.
//!
//! The last section closes the loop: a `Controller` ticks over that same
//! front end while a closed-loop workload (arrivals offset from service
//! progress, 25% batch-class) streams in — each tick observes per-shard
//! queue-latency tails and drain ages, then resizes chunks, adapts the
//! admission cap, and schedules drains toward its latency target.

use std::sync::Arc;

use dreamshard::placer::{self, PlacementRequest};
use dreamshard::runtime::Runtime;
use dreamshard::serve::{
    synthetic_arrivals, ControlConfig, Controller, PlanService, ServeConfig, ShardConfig,
    ShardedFrontEnd, WorkloadCfg,
};
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::tables::{gen_dlrm, split_pools};

fn main() -> dreamshard::Result<()> {
    let rt = Arc::new(Runtime::open_default()?);
    let ds = gen_dlrm(300, 7);
    let (pool, _) = split_pools(&ds, 1);
    let sim = Simulator::new(SimConfig::default());

    // a synthetic open-loop workload: Poisson arrivals, heterogeneous tasks
    let arrivals = synthetic_arrivals(&pool, &WorkloadCfg {
        n_requests: 24,
        device_mix: vec![2, 4, 8],
        min_tables: 6,
        max_tables: 16,
        mean_gap_ms: 2.0,
        seed: 1,
        ..WorkloadCfg::default()
    });

    let placer = placer::by_name(&rt, "dreamshard")?;
    let mut svc = PlanService::new(&rt, placer, ServeConfig {
        capacity: 32,
        chunk: 8,
        ..ServeConfig::default()
    });
    for a in &arrivals {
        let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim)?;
        svc.submit(req)?; // Ok(None) would mean the bounded queue shed it
    }
    println!(
        "queued {} heterogeneous requests; pipelined drain on {} runtime worker(s) ...\n",
        svc.queued(),
        rt.workers()
    );

    let mut done = svc.drain()?;
    done.sort_by_key(|p| p.ticket);
    for p in &done {
        println!(
            "ticket {:>2}  variant d{:<3}  {:>2} tables  queue {:>6.2} ms  \
             plan {:>6.2} ms  cost {:>6.1} ms",
            p.ticket,
            p.variant.0,
            p.plan.placement.len(),
            p.queue_ms,
            p.plan_ms,
            p.plan.eval.latency,
        );
    }
    println!("\n{}", svc.stats().summary());

    // the same idea, sharded: add 128-device requests to the mix and
    // serve through one PlanService per serving variant, each draining
    // on its own thread against the shared runtime worker pool
    let mixed = synthetic_arrivals(&pool, &WorkloadCfg {
        n_requests: 24,
        device_mix: vec![2, 4, 8, 128],
        min_tables: 6,
        max_tables: 16,
        mean_gap_ms: 2.0,
        seed: 2,
        ..WorkloadCfg::default()
    });
    let factory = {
        let rt = Arc::clone(&rt);
        move || placer::by_name(&rt, "dreamshard")
    };
    let mut front = ShardedFrontEnd::new(&rt, factory, ShardConfig {
        per_shard: ServeConfig { capacity: 32, chunk: 8, ..ServeConfig::default() },
        global_cap: 32,
    })?;
    for a in &mixed {
        let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim)?;
        front.submit(req)?; // Ok(None) would mean the global cap shed it
    }
    println!("\nsharded front end: {} requests routed across shards ...", front.queued());
    front.drain()?;
    for sh in front.shards() {
        println!(
            "shard {:<8}  {:>2} plans in {:>2} chunks  queue {:>6.2} ms mean",
            sh.key.label(),
            sh.stats.planned,
            sh.stats.chunks,
            sh.stats.mean_queue_ms(),
        );
    }
    println!("\n{}", front.stats().summary());

    // the closed loop: the same front end, now steered by a Controller —
    // per tick it reads each shard's queue-latency tail, queue depth,
    // and drain-completion age, then actuates the knobs that already
    // exist (AIMD admission cap, lane-chunk resizing, worst-tail-first
    // drain scheduling, SLO-class pressure mode: interactive drains
    // first, batch sheds first)
    let closed = synthetic_arrivals(&pool, &WorkloadCfg {
        n_requests: 24,
        device_mix: vec![2, 4, 8, 128],
        min_tables: 6,
        max_tables: 16,
        mean_gap_ms: 2.0,
        closed_loop: true, // at_ms = gap from the last service progress
        batch_pct: 25,
        seed: 2,
    });
    let mut ctl = Controller::new(ControlConfig { target_ms: 25.0, ..Default::default() });
    println!("\nclosed loop: controller ticks over {} arrivals (25% batch) ...", closed.len());
    for burst in closed.chunks(8) {
        for a in burst {
            let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim)?;
            front.submit_slo(req, a.class, None)?; // Ok(None) = admission shed
        }
        println!("  {}", ctl.tick(&mut front)?.summary());
    }
    // flush the tail directly — the example exits rather than waiting
    // out the controller's idle floor in real time
    front.drain()?;
    println!("\n{}", front.stats().summary());
    Ok(())
}
