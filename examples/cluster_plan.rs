//! Cluster-scale planning: train at 8 devices, plan ~1,000 diverse-dim
//! production-like tables onto 128 devices — the paper's Table-13
//! scenario as a library call. The `Placer` facade routes the 128-device
//! request to the inference-only ultra artifact variant automatically.
//!
//!     cargo run --release --example cluster_plan

use dreamshard::Result;

use dreamshard::coordinator::TrainCfg;
use dreamshard::placer::{self, FitRequest, Placer, PlacementRequest};
use dreamshard::runtime::Runtime;
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::tables::{gen_prod, sample_tasks, split_pools};

fn main() -> Result<()> {
    let rt = std::sync::Arc::new(Runtime::open_default()?);

    // train at small scale (Prod-40 (8)) behind the facade
    let train_ds = gen_prod(400, 42);
    let (pool, _) = split_pools(&train_ds, 1);
    let train_tasks = sample_tasks(&pool, 40, 8, 12, 2);
    let sim8 = Simulator::new(SimConfig::v100());
    let mut agent = placer::by_name(&rt, "dreamshard")?;
    println!("training at 8 devices ...");
    agent.fit(&FitRequest {
        ds: &train_ds,
        tasks: &train_tasks,
        sim: &sim8,
        cfg: TrainCfg::fast(),
        seed: 3,
        verbose: false,
    })?;

    // plan at 128 devices, ~960 tables, unchanged parameters
    let ds = gen_prod(1024, 77);
    let (big_pool, _) = split_pools(&ds, 5);
    let task = sample_tasks(&big_pool, 480.min(big_pool.len()), 128, 1, 6).remove(0);
    let sim = Simulator::new(SimConfig { mem_cap_gb: 40.0, ..SimConfig::v100() });
    let total_gb: f64 =
        task.table_ids.iter().map(|&i| ds.tables[i].size_gb() as f64 * 3.0).sum();
    println!(
        "planning {} tables ({:.2} TB with optimizer state) on 128 devices ...",
        task.n_tables(),
        total_gb / 1024.0
    );

    let req = PlacementRequest::for_runtime(&rt, &ds, &task, &sim)?;
    let t0 = std::time::Instant::now(); // lint: allow(clock-transitive) — example prints wall-clock timings, not replayed
    let ours = agent.place(&req)?;
    let plan_s = t0.elapsed().as_secs_f64();
    let dim = placer::by_name(&rt, "greedy:dim")?.place(&req)?;
    println!("planned in {plan_s:.1}s");
    println!("  dim-based expert : {:.1} ms", dim.eval.latency);
    println!("  DreamShard       : {:.1} ms", ours.eval.latency);

    // per-device balance summary
    let mems: Vec<f64> = ours.eval.devices.iter().map(|d| d.mem_gb).collect();
    let max_mem = mems.iter().cloned().fold(0.0, f64::max);
    println!(
        "  max device memory {:.1} GB (cap {:.0} GB), max tables/device {}",
        max_mem,
        sim.cfg.mem_cap_gb,
        ours.eval.devices.iter().map(|d| d.n_tables).max().unwrap_or(0)
    );
    Ok(())
}
