//! Cluster-scale planning: train at 8 devices, plan ~1,000 diverse-dim
//! production-like tables onto 128 devices through the inference-only
//! ultra artifact — the paper's Table-13 scenario as a library call.
//!
//!     cargo run --release --example cluster_plan

use dreamshard::Result;

use dreamshard::baselines::{greedy_placement, Expert};
use dreamshard::coordinator::{DreamShard, TrainCfg, Variant};
use dreamshard::runtime::Runtime;
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::tables::{gen_prod, sample_tasks, split_pools};
use dreamshard::util::Rng;

fn main() -> Result<()> {
    let rt = Runtime::open_default()?;
    let mut rng = Rng::new(3);

    // train at small scale (Prod-40 (8))
    let train_ds = gen_prod(400, 42);
    let (pool, _) = split_pools(&train_ds, 1);
    let train_tasks = sample_tasks(&pool, 40, 8, 12, 2);
    let sim8 = Simulator::new(SimConfig::v100());
    let mut agent = DreamShard::new(&rt, 8, TrainCfg::fast(), &mut rng)?;
    println!("training at 8 devices ...");
    agent.train(&rt, &sim8, &train_ds, &train_tasks, &mut rng)?;

    // plan at 128 devices, ~960 tables, unchanged parameters
    let ds = gen_prod(1024, 77);
    let (big_pool, _) = split_pools(&ds, 5);
    let task = sample_tasks(&big_pool, 480.min(big_pool.len()), 128, 1, 6).remove(0);
    let sim = Simulator::new(SimConfig { mem_cap_gb: 40.0, ..SimConfig::v100() });
    let total_gb: f64 =
        task.table_ids.iter().map(|&i| ds.tables[i].size_gb() as f64 * 3.0).sum();
    println!(
        "planning {} tables ({:.2} TB with optimizer state) on 128 devices ...",
        task.n_tables(),
        total_gb / 1024.0
    );

    let var = Variant::for_devices(&rt, 128)?;
    let t0 = std::time::Instant::now();
    let ep = agent
        .run_episodes_var(&rt, &sim, &ds, &task, 1, false, false, &mut rng, &var, false)?
        .remove(0);
    let plan_s = t0.elapsed().as_secs_f64();
    let ours = sim.evaluate(&ds, &task, &ep.placement);
    let dim = sim.evaluate(&ds, &task, &greedy_placement(&ds, &task, &sim, Expert::Dim));
    println!("planned in {plan_s:.1}s");
    println!("  dim-based expert : {:.1} ms", dim.latency);
    println!("  DreamShard       : {:.1} ms", ours.latency);

    // per-device balance summary
    let mems: Vec<f64> = ours.devices.iter().map(|d| d.mem_gb).collect();
    let max_mem = mems.iter().cloned().fold(0.0, f64::max);
    println!(
        "  max device memory {:.1} GB (cap {:.0} GB), max tables/device {}",
        max_mem,
        sim.cfg.mem_cap_gb,
        ours.devices.iter().map(|d| d.n_tables).max().unwrap_or(0)
    );
    Ok(())
}
