//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! 1. Plan: train DreamShard and place the DLRM model's 26 embedding
//!    tables on a simulated 4-GPU cluster (vs random / expert baselines).
//! 2. Train: run the actual DLRM model (Layer-2 JAX, embedding bags
//!    through the Layer-1 Pallas kernel, AOT `dlrm_train` artifact) for a
//!    few hundred steps on synthetic click data — from rust, via PJRT,
//!    logging the loss curve.
//! 3. Report: simulated distributed step time under each placement and
//!    the measured loss curve (recorded in EXPERIMENTS.md).
//!
//!     make artifacts && cargo run --release --example dlrm_e2e

use dreamshard::Result;
use std::io::Write;

use dreamshard::coordinator::TrainCfg;
use dreamshard::placer::{self, FitRequest, Placer, PlacementRequest};
use dreamshard::runtime::{to_f32_vec, Runtime, TensorF32, TensorI32};
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::tables::{gen_dlrm, sample_tasks, split_pools, Dataset, Table, Task};
use dreamshard::util::Rng;

/// Synthetic click batch with a planted signal: the label depends on one
/// dense feature and on whether table 0's bag contains a "hot" index, so
/// a learning model must use BOTH the dense path and the embeddings.
struct BatchGen {
    hash: Vec<u64>,
    b: usize,
    n_dense: usize,
    pool: usize,
    rng: Rng,
}

impl BatchGen {
    fn next(&mut self) -> (TensorF32, TensorI32, TensorF32, TensorF32) {
        let (b, nd, n, p) = (self.b, self.n_dense, self.hash.len(), self.pool);
        let mut dense = TensorF32::zeros(&[b, nd]);
        let mut idx = TensorI32::zeros(&[b, n, p]);
        let mut w = TensorF32::zeros(&[b, n, p]);
        let mut labels = TensorF32::zeros(&[b]);
        for i in 0..b {
            for j in 0..nd {
                dense.set(&[i, j], self.rng.f32());
            }
            let mut hot = false;
            for t in 0..n {
                let k = 1 + self.rng.below(p); // actual pooling factor
                for s in 0..k {
                    let v = self.rng.below(self.hash[t] as usize) as i32;
                    idx.data[(i * n + t) * p + s] = v;
                    w.set(&[i, t, s], 1.0);
                    if t == 0 && v % 7 == 0 {
                        hot = true;
                    }
                }
            }
            let logit = 2.0 * (dense.get(&[i, 0]) - 0.5) + if hot { 1.5 } else { -0.5 };
            labels.data[i] = if self.rng.f32() < 1.0 / (1.0 + (-logit).exp()) { 1.0 } else { 0.0 };
        }
        (dense, idx, w, labels)
    }
}

/// Wrap the DLRM model's tables as a placement task for the planner.
fn dlrm_as_task(hash: &[u64]) -> (Dataset, Task) {
    let mut rng = Rng::new(5);
    let base = gen_dlrm(hash.len(), 9);
    let tables: Vec<Table> = hash
        .iter()
        .zip(base.tables.iter())
        .map(|(&h, proto)| Table {
            dim: 32,
            hash_size: h,
            pooling: 1.0 + rng.f32() * 7.0,
            bins: proto.bins,
        })
        .collect();
    let ds = Dataset { name: "dlrm-e2e".into(), tables };
    let task = Task { table_ids: (0..hash.len()).collect(), n_devices: 4 };
    (ds, task)
}

fn main() -> Result<()> {
    let rt = std::sync::Arc::new(Runtime::open_default()?);
    let hash = rt.manifest.dlrm_hash.clone();
    dreamshard::ensure!(
        !hash.is_empty(),
        "the DLRM end-to-end example needs the XLA backend: run `make artifacts` and build with --features xla"
    );
    let b = rt.manifest.consts["DLRM_B"] as usize;
    let nd = rt.manifest.consts["DLRM_NDENSE"] as usize;
    let pool = rt.manifest.consts["DLRM_POOL"] as usize;
    let n_params = rt.manifest.params["dlrm"].total;
    println!(
        "DLRM: {} tables, {} params ({:.1} MB), batch {b}",
        hash.len(),
        n_params,
        n_params as f64 * 4.0 / 1e6
    );

    // ---- 1. placement planning ------------------------------------------
    let (ds, task) = dlrm_as_task(&hash);
    let sim = Simulator::new(SimConfig::default());
    // train the planner on generic DLRM tasks, then place this model
    let pool_ds = gen_dlrm(200, 42);
    let (pool_tr, _) = split_pools(&pool_ds, 1);
    let plan_tasks = sample_tasks(&pool_tr, 26, 4, 12, 2);
    let mut agent = placer::by_name(&rt, "dreamshard")?;
    println!("\ntraining the placement agent ...");
    agent.fit(&FitRequest {
        ds: &pool_ds,
        tasks: &plan_tasks,
        sim: &sim,
        cfg: TrainCfg::fast(),
        seed: 0,
        verbose: false,
    })?;

    let req = PlacementRequest::for_runtime(&rt, &ds, &task, &sim)?;
    let p_ds = agent.place(&req)?;
    println!("\nsimulated distributed step time for the DLRM embedding stage:");
    for name in ["random", "greedy:dim"] {
        let plan = placer::by_name(&rt, name)?.place(&req)?;
        println!("  {:<12} {:.2} ms", plan.strategy, plan.eval.latency);
    }
    println!("  {:<12} {:.2} ms", "DreamShard", p_ds.eval.latency);

    // ---- 2. actually train the model through the AOT artifact ------------
    let steps: usize = std::env::var("DLRM_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(300); // lint: allow(env-discipline) — example-local step-count knob, not library config
    let mut theta = rt.init_params("dlrm", &mut Rng::new(7))?;
    let mut m = vec![0.0f32; n_params];
    let mut v = vec![0.0f32; n_params];
    let mut gen = BatchGen { hash: hash.clone(), b, n_dense: nd, pool, rng: Rng::new(11) };
    let mut curve = vec![];
    println!("\ntraining DLRM for {steps} steps via the dlrm_train artifact ...");
    let t0 = std::time::Instant::now(); // lint: allow(clock-transitive) — example prints wall-clock timings, not replayed
    for step in 0..steps {
        let (dense, idx, w, labels) = gen.next();
        let out = rt.run("dlrm_train", &[
            TensorF32::from_vec(std::mem::take(&mut theta), &[n_params]).into_value(),
            TensorF32::from_vec(std::mem::take(&mut m), &[n_params]).into_value(),
            TensorF32::from_vec(std::mem::take(&mut v), &[n_params]).into_value(),
            TensorF32::scalar1((step + 1) as f32).into_value(),
            TensorF32::scalar1(2e-3).into_value(),
            dense.value(),
            idx.value(),
            w.value(),
            labels.value(),
        ])?;
        theta = to_f32_vec(&out[0], n_params)?;
        m = to_f32_vec(&out[1], n_params)?;
        v = to_f32_vec(&out[2], n_params)?;
        let loss = to_f32_vec(&out[3], 1)?[0];
        curve.push(loss);
        if step % 20 == 0 || step + 1 == steps {
            println!("  step {step:>4}: loss {loss:.4}");
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("trained {steps} steps in {dt:.1}s ({:.1} ms/step)", dt / steps as f64 * 1e3);

    // loss must actually go down — this is the end-to-end signal
    let head: f32 = curve[..20.min(curve.len())].iter().sum::<f32>() / 20.0_f32.min(curve.len() as f32);
    let tail: f32 = curve[curve.len().saturating_sub(20)..].iter().sum::<f32>() / 20.0_f32.min(curve.len() as f32);
    println!("loss: first-20 avg {head:.4} -> last-20 avg {tail:.4}");
    dreamshard::ensure!(tail < head, "DLRM loss did not decrease");

    std::fs::create_dir_all("bench_out")?;
    let mut f = std::fs::File::create("bench_out/dlrm_e2e_loss.csv")?;
    writeln!(f, "step,loss")?;
    for (i, l) in curve.iter().enumerate() {
        writeln!(f, "{i},{l}")?;
    }
    println!("loss curve -> bench_out/dlrm_e2e_loss.csv");
    Ok(())
}
